//! Curvature-structure frontier battery: the contracts that let the
//! KPSVD and iterative-inverse newcomers share the Preconditioner
//! registry with the original structures.
//!
//! - KPSVD at R=1 is *bitwise* the factored-Tikhonov block-diagonal
//!   inverse; at R=2 its dense fit of the damped target is strictly
//!   better (the target has exact Kronecker rank 2).
//! - ikfac with drift threshold 0 rebuilds at every boundary, so its
//!   whole training trajectory is bit-identical to `blkdiag`.
//! - Both newcomers checkpoint/restore bit-exactly mid-run — ikfac
//!   including a live incremental-update record (v4).
//! - Both shard across ranks: `sharded_build` at any rank count
//!   installs exactly the single-process inverse.

use std::sync::Arc;

use kfac::backend::{ModelBackend, RustBackend};
use kfac::data::mnist_like;
use kfac::dist::local::LocalGroup;
use kfac::dist::sharded_build;
use kfac::dist::trainer::{run_local_ranks, run_ranks_with};
use kfac::fisher::ikfac::IkfacPrecond;
use kfac::fisher::kpsvd::{fitted_dense, KpsvdPrecond};
use kfac::fisher::{precond, PrecondRef, RawStats};
use kfac::linalg::kron::kron;
use kfac::nn::{Act, Arch, Params};
use kfac::optim::{Kfac, KfacConfig, Optimizer};
use kfac::rng::Rng;

fn assert_params_bit_equal(a: &Params, b: &Params, what: &str) {
    assert_eq!(a.0.len(), b.0.len(), "{what}: layer count");
    for (i, (ma, mb)) in a.0.iter().zip(b.0.iter()).enumerate() {
        assert_eq!(ma.data.len(), mb.data.len(), "{what}: layer {i} size");
        for (j, (va, vb)) in ma.data.iter().zip(mb.data.iter()).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{what}: layer {i} elem {j}: {va} != {vb}"
            );
        }
    }
}

fn tiny_setup() -> (Arch, kfac::data::Dataset) {
    let arch = Arch::autoencoder(&[16, 8, 4, 8, 16], Act::Tanh);
    let ds = mnist_like::autoencoder_dataset(64, 4, 5);
    (arch, ds)
}

fn tiny_stats(seed: u64) -> (Arch, Params, RawStats, Params) {
    let (arch, ds) = tiny_setup();
    let mut backend = RustBackend::new(arch.clone());
    let params = arch.sparse_init(&mut Rng::new(seed));
    let (_, grads, stats) = backend.grad_and_stats(&params, &ds.x, &ds.y, 32, 9);
    (arch, params, stats, grads)
}

/// Run `iters` full-batch K-FAC steps with the given preconditioner and
/// return (per-step loss bits, final params).
fn run_trajectory(pre: PrecondRef, t_inv: usize, iters: usize) -> (Vec<u64>, Params) {
    let (arch, ds) = tiny_setup();
    let cfg = KfacConfig {
        precond: pre,
        lambda0: 5.0,
        t_inv,
        refresh_async: false,
        ..Default::default()
    };
    let mut opt = Kfac::try_new(&arch, cfg).expect("dense arch accepted");
    let mut backend = RustBackend::new(arch.clone());
    let mut params = arch.sparse_init(&mut Rng::new(23));
    let mut losses = Vec::with_capacity(iters);
    for _ in 0..iters {
        losses.push(opt.step(&mut backend, &mut params, &ds.x, &ds.y).loss.to_bits());
    }
    (losses, params)
}

// ---------------------------------------------------------------------------
// KPSVD rank contracts
// ---------------------------------------------------------------------------

#[test]
fn kpsvd_r1_inverse_is_bitwise_blockdiag() {
    let (_arch, _params, stats, grads) = tiny_stats(5);
    for gamma in [0.1, 0.5, 2.0] {
        let want = precond::block_diag().build(&stats, gamma).apply(&grads);
        let got = KpsvdPrecond::new(1).build(&stats, gamma).apply(&grads);
        assert_params_bit_equal(&want, &got, &format!("kpsvd R=1 apply, gamma={gamma}"));
    }
}

#[test]
fn kpsvd_r1_trajectory_is_bitwise_blockdiag() {
    let (l_blk, p_blk) = run_trajectory(precond::block_diag(), 3, 8);
    let (l_kp, p_kp) = run_trajectory(Arc::new(KpsvdPrecond::new(1)), 3, 8);
    assert_eq!(l_blk, l_kp, "kpsvd R=1 loss trajectory diverged from blkdiag");
    assert_params_bit_equal(&p_blk, &p_kp, "kpsvd R=1 final params");
}

#[test]
fn kpsvd_r2_fit_is_strictly_better_than_r1() {
    // The damped target Ā⊗G + γ²I⊗I has exact Kronecker rank 2, so the
    // rank-2 rearrangement fit must beat the rank-1 fit on every layer
    // with a nontrivial spectrum; aggregate strictly.
    let (_arch, _params, stats, _grads) = tiny_stats(5);
    let gamma = 0.7;
    let (mut err1, mut err2) = (0.0f64, 0.0f64);
    for i in 0..stats.num_layers() {
        let target = kron(&stats.aa[i], &stats.gg[i]).add_diag(gamma * gamma);
        for (r, err) in [(1usize, &mut err1), (2usize, &mut err2)] {
            let fit = fitted_dense(&stats.aa[i], &stats.gg[i], gamma, r);
            *err += target.sub(&fit).frob_norm().powi(2);
        }
    }
    let (err1, err2) = (err1.sqrt(), err2.sqrt());
    assert!(
        err2 < err1 * 1e-6,
        "R=2 must essentially nail the rank-2 target: R1 {err1:.3e} R2 {err2:.3e}"
    );
}

// ---------------------------------------------------------------------------
// ikfac trajectory contract
// ---------------------------------------------------------------------------

#[test]
fn ikfac_zero_drift_threshold_trajectory_is_bitwise_blockdiag() {
    // Threshold 0 declines every incremental update, so each t_inv
    // boundary falls back to the full rebuild — which is numerically the
    // block-diagonal factored-Tikhonov build.
    let (l_blk, p_blk) = run_trajectory(precond::block_diag(), 4, 10);
    let (l_ik, p_ik) = run_trajectory(Arc::new(IkfacPrecond::new(4, 0.0)), 4, 10);
    assert_eq!(l_blk, l_ik, "ikfac drift=0 loss trajectory diverged from blkdiag");
    assert_params_bit_equal(&p_blk, &p_ik, "ikfac drift=0 final params");
}

// ---------------------------------------------------------------------------
// Checkpoint roundtrips (bit-exact resume)
// ---------------------------------------------------------------------------

fn checkpoint_roundtrip_is_bit_exact(make_pre: impl Fn() -> PrecondRef, what: &str) {
    let (arch, ds) = tiny_setup();
    let cfg = || KfacConfig {
        precond: make_pre(),
        lambda0: 5.0,
        t_inv: 4,
        refresh_async: false,
        ..Default::default()
    };
    let init = arch.sparse_init(&mut Rng::new(31));

    let mut backend = RustBackend::new(arch.clone());
    let mut opt = Kfac::try_new(&arch, cfg()).unwrap();
    let mut params = init.clone();
    for _ in 0..7 {
        opt.step(&mut backend, &mut params, &ds.x, &ds.y);
    }
    let snap = opt.state();
    let params_snap = params.clone();

    // reference: keep stepping the original optimizer
    let mut want_losses = Vec::new();
    for _ in 0..5 {
        want_losses.push(opt.step(&mut backend, &mut params, &ds.x, &ds.y).loss.to_bits());
    }

    // resume: fresh optimizer of the same configuration
    let mut backend2 = RustBackend::new(arch.clone());
    let mut opt2 = Kfac::try_new(&arch, cfg()).unwrap();
    opt2.load_state(&snap).expect("restore");
    let mut params2 = params_snap;
    let mut got_losses = Vec::new();
    for _ in 0..5 {
        got_losses.push(opt2.step(&mut backend2, &mut params2, &ds.x, &ds.y).loss.to_bits());
    }

    assert_eq!(want_losses, got_losses, "{what}: post-restore loss trace diverged");
    assert_params_bit_equal(&params, &params2, &format!("{what}: post-restore params"));
}

#[test]
fn kpsvd_checkpoint_roundtrip_is_bit_exact() {
    checkpoint_roundtrip_is_bit_exact(|| Arc::new(KpsvdPrecond::new(2)), "kpsvd R=2");
}

#[test]
fn ikfac_checkpoint_roundtrip_is_bit_exact() {
    // Huge drift threshold: every boundary past bootstrap takes the
    // incremental Woodbury path, so the snapshot carries a live v4
    // update record and restore exercises the replay.
    checkpoint_roundtrip_is_bit_exact(|| Arc::new(IkfacPrecond::new(4, 1e300)), "ikfac");
    let (arch, ds) = tiny_setup();
    let cfg = KfacConfig {
        precond: Arc::new(IkfacPrecond::new(4, 1e300)),
        lambda0: 5.0,
        t_inv: 4,
        refresh_async: false,
        ..Default::default()
    };
    let mut backend = RustBackend::new(arch.clone());
    let mut opt = Kfac::try_new(&arch, cfg).unwrap();
    let mut params = arch.sparse_init(&mut Rng::new(31));
    for _ in 0..9 {
        opt.step(&mut backend, &mut params, &ds.x, &ds.y);
    }
    let snap = opt.state();
    assert!(snap.scalar("upd_gamma").is_some(), "expected a live incremental-update record");
}

// ---------------------------------------------------------------------------
// Distributed sharding parity
// ---------------------------------------------------------------------------

#[test]
fn newcomers_sharded_build_matches_plain_build_bitwise() {
    let (_arch, _params, stats, grads) = tiny_stats(5);
    let gamma = 0.3;
    let cases: Vec<(&str, PrecondRef)> = vec![
        ("kpsvd R=1", Arc::new(KpsvdPrecond::new(1))),
        ("kpsvd R=2", Arc::new(KpsvdPrecond::new(2))),
        ("ikfac", Arc::new(IkfacPrecond::new(4, 0.5))),
    ];
    for (what, p) in &cases {
        let want = p.build(&stats, gamma).apply(&grads);
        // ranks=1 must be the degenerate no-op path
        let (p_ref, stats_ref, grads_ref) = (p, &stats, &grads);
        let mut one = run_local_ranks(1, |_rank, coll| {
            sharded_build(p_ref.as_ref(), stats_ref, gamma, coll.as_ref())
                .expect("ranks=1 build")
                .apply(grads_ref)
        });
        assert_params_bit_equal(&want, &one.remove(0), &format!("{what}, ranks=1"));
        for n in [2usize, 3] {
            let outs = run_ranks_with(LocalGroup::create(n), &|_rank, coll| {
                sharded_build(p_ref.as_ref(), stats_ref, gamma, coll.as_ref())
                    .expect("sharded build")
                    .apply(grads_ref)
            });
            for (rank, got) in outs.iter().enumerate() {
                assert_params_bit_equal(&want, got, &format!("{what}, {n}-rank, rank {rank}"));
            }
        }
    }
}
