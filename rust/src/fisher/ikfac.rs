//! Iterative K-FAC — incremental inverse maintenance (Chen 2021,
//! "Iterative K-FAC: accelerating K-FAC via online rank-k inverse
//! corrections").
//!
//! A full K-FAC refresh pays two `O(n³)` factorizations per layer at
//! every `t_inv` boundary even when the factor statistics barely moved
//! between boundaries (the EMA makes consecutive factors differ by a
//! heavily down-weighted batch). This structure keeps the inverses of
//! a **base** factor snapshot and, at each boundary, absorbs the drift
//!
//! `Δ = damped(Ā_now, γ_now) − damped(Ā_base, γ_base)`
//!
//! by a memoryless rank-k Woodbury correction: with `V Λ Vᵀ` the top-k
//! eigenpairs of `Δ` (deterministic subspace iteration,
//! [`sym_topk`](crate::linalg::eig::sym_topk), `O(n²k)`),
//!
//! `(A_b + VΛVᵀ)⁻¹ = A_b⁻¹ − W (Λ⁻¹ + VᵀW)⁻¹ Wᵀ`,  `W = A_b⁻¹ V`.
//!
//! Corrections are always taken against the base snapshot (never
//! chained), so the applied inverse is a pure function of
//! `(base snapshot, latest stats snapshot, γ)` — which is exactly what
//! lets checkpoint resume rebuild the base and replay one recorded
//! update bit-for-bit. When the relative drift
//! `max_i ‖Δᵢ‖_F / ‖damped baseᵢ‖_F` exceeds a threshold
//! (`KFAC_IKFAC_DRIFT`, default 0.5) the update declines with
//! [`UpdateOutcome::NeedsRebuild`] and the optimizer runs the ordinary
//! full rebuild, which re-bases the structure. The correction rank is
//! `KFAC_IKFAC_RANK` (default 4).
//!
//! Outside the sync single-process fast path (async refresh, γ line
//! search, distributed sharded builds) the optimizer never offers
//! deltas — those boundaries fall back to full builds, identical to
//! block-diagonal behavior.

use super::damping::damped_factors;
use super::precond::Preconditioner;
use super::stats::RawStats;
use super::{FisherInverse, UpdateOutcome};
use crate::linalg::chol::spd_inverse;
use crate::linalg::eig::sym_topk;
use crate::linalg::Mat;
use crate::nn::Params;

/// Subspace-iteration rounds inside [`sym_topk`] per factor. Fixed so
/// the correction is a deterministic pure function of its inputs.
const TOPK_ITERS: usize = 12;

/// Relative eigenvalue floor below which drift directions are dropped.
const TOPK_TOL: f64 = 1e-12;

/// Cached base factorization plus the rank-k-corrected inverses the
/// optimizer actually applies.
pub struct IkfacInverse {
    /// Raw (undamped) factor snapshot the base was built from.
    base_aa: Vec<Mat>,
    base_gg: Vec<Mat>,
    /// Damped base factors (what the base inverses invert).
    base_ad: Vec<Mat>,
    base_gd: Vec<Mat>,
    /// Inverses of the damped base factors.
    base_ainv: Vec<Mat>,
    base_ginv: Vec<Mat>,
    /// Corrected inverses currently in effect (== base until the first
    /// accepted update).
    cur_ainv: Vec<Mat>,
    cur_ginv: Vec<Mat>,
    rank: usize,
    drift_threshold: f64,
}

impl IkfacInverse {
    /// Full (re)build: numerically identical per-layer work to
    /// [`BlockDiagInverse::build`](super::BlockDiagInverse::build),
    /// plus snapshotting the base for later corrections.
    pub fn build(stats: &RawStats, gamma: f64, rank: usize, drift_threshold: f64) -> IkfacInverse {
        let l = stats.num_layers();
        let built = crate::par::par_map_send(l, 1, |i| {
            super::check_factors_finite("ikfac", i, &stats.aa[i], &stats.gg[i]);
            let (ad, gd) = damped_factors(&stats.aa[i], &stats.gg[i], gamma);
            let ainv = spd_inverse(&ad);
            let ginv = spd_inverse(&gd);
            (ad, gd, ainv, ginv)
        });
        let mut base_ad = Vec::with_capacity(l);
        let mut base_gd = Vec::with_capacity(l);
        let mut base_ainv = Vec::with_capacity(l);
        let mut base_ginv = Vec::with_capacity(l);
        for (ad, gd, ainv, ginv) in built {
            base_ad.push(ad);
            base_gd.push(gd);
            base_ainv.push(ainv);
            base_ginv.push(ginv);
        }
        IkfacInverse {
            base_aa: stats.aa.clone(),
            base_gg: stats.gg.clone(),
            base_ad,
            base_gd,
            cur_ainv: base_ainv.clone(),
            cur_ginv: base_ginv.clone(),
            base_ainv,
            base_ginv,
            rank,
            drift_threshold,
        }
    }

    /// Rank-k Woodbury correction of `base_inv = base⁻¹` toward
    /// `(base + Δ)⁻¹`. `None` when the correction degenerates
    /// numerically (caller falls back to a full rebuild).
    fn woodbury(base_inv: &Mat, delta: &Mat, rank: usize) -> Option<Mat> {
        let (lam, v) = sym_topk(delta, rank, TOPK_ITERS, TOPK_TOL);
        if lam.is_empty() {
            return Some(base_inv.clone());
        }
        let k = lam.len();
        let w = base_inv.matmul(&v); // n×k
        let mut s = v.matmul_tn(&w); // VᵀW, k×k
        for (j, &l) in lam.iter().enumerate() {
            s.set(j, j, s.at(j, j) + 1.0 / l);
        }
        let sinv = s.inverse();
        if !sinv.all_finite() {
            return None;
        }
        let corr = w.matmul(&sinv).matmul_nt(&w);
        let out = base_inv.sub(&corr).symmetrize();
        if out.all_finite() {
            Some(out)
        } else {
            None
        }
    }
}

impl FisherInverse for IkfacInverse {
    fn apply(&self, grads: &Params) -> Params {
        Params(
            grads
                .0
                .iter()
                .enumerate()
                .map(|(i, v)| self.cur_ginv[i].matmul(&v.matmul(&self.cur_ainv[i])))
                .collect(),
        )
    }

    fn update(&mut self, stats_delta: &RawStats, gamma: f64) -> UpdateOutcome {
        let l = self.base_aa.len();
        if stats_delta.aa.len() != l || stats_delta.gg.len() != l {
            return UpdateOutcome::NeedsRebuild;
        }
        // Pass 1: form the damped-factor drifts and the trigger norm.
        // Nothing is mutated until every layer's correction succeeds.
        let mut deltas = Vec::with_capacity(l);
        let mut drift = 0.0f64;
        for i in 0..l {
            let aa_now = self.base_aa[i].add(&stats_delta.aa[i]);
            let gg_now = self.base_gg[i].add(&stats_delta.gg[i]);
            if !aa_now.all_finite() || !gg_now.all_finite() {
                return UpdateOutcome::NeedsRebuild;
            }
            let (ad_now, gd_now) = damped_factors(&aa_now, &gg_now, gamma);
            let da = ad_now.sub(&self.base_ad[i]);
            let dg = gd_now.sub(&self.base_gd[i]);
            let ra = da.frob_norm() / self.base_ad[i].frob_norm().max(1e-300);
            let rg = dg.frob_norm() / self.base_gd[i].frob_norm().max(1e-300);
            drift = drift.max(ra).max(rg);
            deltas.push((da, dg));
        }
        if !drift.is_finite() || drift > self.drift_threshold {
            return UpdateOutcome::NeedsRebuild;
        }
        // Pass 2: rank-k corrections, all-or-nothing.
        let mut corrected = Vec::with_capacity(l);
        for (i, (da, dg)) in deltas.iter().enumerate() {
            let ca = match Self::woodbury(&self.base_ainv[i], da, self.rank) {
                Some(m) => m,
                None => return UpdateOutcome::NeedsRebuild,
            };
            let cg = match Self::woodbury(&self.base_ginv[i], dg, self.rank) {
                Some(m) => m,
                None => return UpdateOutcome::NeedsRebuild,
            };
            corrected.push((ca, cg));
        }
        for (i, (ca, cg)) in corrected.into_iter().enumerate() {
            self.cur_ainv[i] = ca;
            self.cur_ginv[i] = cg;
        }
        UpdateOutcome::Updated
    }
}

/// Correction rank from `KFAC_IKFAC_RANK` (default 4).
pub fn rank_from_env() -> usize {
    match std::env::var("KFAC_IKFAC_RANK") {
        Err(_) => 4,
        Ok(s) => match s.parse::<usize>() {
            Ok(v) if v >= 1 => v,
            _ => panic!("KFAC_IKFAC_RANK must be an integer ≥ 1 (got '{s}')"),
        },
    }
}

/// Rebuild trigger from `KFAC_IKFAC_DRIFT` (default 0.5): relative
/// Frobenius drift above which `update` declines. `0` forces a full
/// rebuild at every boundary (bit-identical to blkdiag trajectories).
pub fn drift_from_env() -> f64 {
    match std::env::var("KFAC_IKFAC_DRIFT") {
        Err(_) => 0.5,
        Ok(s) => match s.parse::<f64>() {
            Ok(v) if v.is_finite() && v >= 0.0 => v,
            _ => panic!("KFAC_IKFAC_DRIFT must be a finite number ≥ 0 (got '{s}')"),
        },
    }
}

/// Iterative K-FAC preconditioner: registered as `"ikfac"` (CLI
/// `kfac_ikfac`).
pub struct IkfacPrecond {
    rank: usize,
    drift: f64,
}

impl IkfacPrecond {
    pub fn new(rank: usize, drift: f64) -> IkfacPrecond {
        assert!(rank >= 1, "ikfac: correction rank must be ≥ 1 (got {rank})");
        assert!(drift.is_finite() && drift >= 0.0, "ikfac: drift threshold must be ≥ 0");
        IkfacPrecond { rank, drift }
    }
}

impl Preconditioner for IkfacPrecond {
    fn name(&self) -> &str {
        "ikfac"
    }

    fn build(&self, stats: &RawStats, gamma: f64) -> Box<dyn FisherInverse + Send> {
        Box::new(IkfacInverse::build(stats, gamma, self.rank, self.drift))
    }

    fn incremental(&self) -> bool {
        true
    }

    fn layer_part_len(&self, stats: &RawStats, layer: usize) -> Option<usize> {
        let a = stats.aa[layer].rows;
        let g = stats.gg[layer].rows;
        Some(a * a + g * g)
    }

    fn build_layer_part(&self, stats: &RawStats, gamma: f64, layer: usize) -> Vec<f64> {
        // Mirrors IkfacInverse::build's per-layer closure exactly so a
        // sharded refresh is bitwise identical to a replicated one.
        super::check_factors_finite("ikfac", layer, &stats.aa[layer], &stats.gg[layer]);
        let (ad, gd) = damped_factors(&stats.aa[layer], &stats.gg[layer], gamma);
        let ainv = spd_inverse(&ad);
        let ginv = spd_inverse(&gd);
        let mut out = ainv.data;
        out.extend_from_slice(&ginv.data);
        out
    }

    fn assemble_parts(
        &self,
        stats: &RawStats,
        gamma: f64,
        parts: &[Vec<f64>],
    ) -> Option<Box<dyn FisherInverse + Send>> {
        if parts.len() != stats.num_layers() {
            return None;
        }
        let mut base_ainv = Vec::with_capacity(parts.len());
        let mut base_ginv = Vec::with_capacity(parts.len());
        let mut base_ad = Vec::with_capacity(parts.len());
        let mut base_gd = Vec::with_capacity(parts.len());
        for (layer, part) in parts.iter().enumerate() {
            let a = stats.aa[layer].rows;
            let g = stats.gg[layer].rows;
            if part.len() != a * a + g * g {
                return None;
            }
            base_ainv.push(Mat::from_vec(a, a, part[..a * a].to_vec()));
            base_ginv.push(Mat::from_vec(g, g, part[a * a..].to_vec()));
            let (ad, gd) = damped_factors(&stats.aa[layer], &stats.gg[layer], gamma);
            base_ad.push(ad);
            base_gd.push(gd);
        }
        Some(Box::new(IkfacInverse {
            base_aa: stats.aa.clone(),
            base_gg: stats.gg.clone(),
            base_ad,
            base_gd,
            cur_ainv: base_ainv.clone(),
            cur_ginv: base_ginv.clone(),
            base_ainv,
            base_ginv,
            rank: self.rank,
            drift_threshold: self.drift,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fisher::stats::KfacStats;
    use crate::nn::net::Net;
    use crate::nn::{Act, Arch, LossKind};
    use crate::rng::Rng;

    fn toy_stats_pair() -> (Arch, RawStats, RawStats) {
        // Two EMA snapshots of the same toy problem: `base` after one
        // batch, `moved` after folding in a second batch.
        let arch =
            Arch::new(vec![5, 4, 3], vec![Act::Tanh, Act::Identity], LossKind::SoftmaxCe);
        let net = Net::new(arch.clone());
        let mut rng = Rng::new(1);
        let p = arch.glorot_init(&mut rng);
        let mut st = KfacStats::new(&arch);
        for _ in 0..2 {
            let x = Mat::randn(64, 5, 1.0, &mut rng);
            let fwd = net.forward(&p, &x);
            let gs = net.sampled_backward(&p, &fwd, &mut rng);
            st.update(&RawStats::from_batch(&fwd, &gs));
        }
        let base = st.s.clone();
        let x = Mat::randn(64, 5, 1.0, &mut rng);
        let fwd = net.forward(&p, &x);
        let gs = net.sampled_backward(&p, &fwd, &mut rng);
        st.update(&RawStats::from_batch(&fwd, &gs));
        (arch, base, st.s)
    }

    fn rand_grads(arch: &Arch, seed: u64) -> crate::nn::Params {
        let mut rng = Rng::new(seed);
        crate::nn::Params(
            (0..arch.num_layers())
                .map(|i| {
                    let (r, c) = arch.weight_shape(i);
                    Mat::randn(r, c, 1.0, &mut rng)
                })
                .collect(),
        )
    }

    #[test]
    fn zero_delta_update_is_a_noop() {
        let (arch, base, _) = toy_stats_pair();
        let gamma = 0.5;
        let mut inv = IkfacInverse::build(&base, gamma, 4, 0.0);
        let g = rand_grads(&arch, 7);
        let before = inv.apply(&g);
        let zero = base.delta_from(&base);
        assert_eq!(inv.update(&zero, gamma), UpdateOutcome::Updated);
        let after = inv.apply(&g);
        for (a, b) in before.0.iter().zip(after.0.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn drift_trigger_declines_without_mutation() {
        let (arch, base, moved) = toy_stats_pair();
        let gamma = 0.5;
        // Threshold 0: any real drift must decline and leave the
        // inverse untouched.
        let mut inv = IkfacInverse::build(&base, gamma, 4, 0.0);
        let g = rand_grads(&arch, 8);
        let before = inv.apply(&g);
        let delta = moved.delta_from(&base);
        assert_eq!(inv.update(&delta, gamma), UpdateOutcome::NeedsRebuild);
        let after = inv.apply(&g);
        for (a, b) in before.0.iter().zip(after.0.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn accepted_update_moves_toward_full_rebuild() {
        // The corrected inverse must be a strictly better proxy for
        // the rebuilt inverse than the stale base inverse is.
        let (arch, base, moved) = toy_stats_pair();
        let gamma = 0.5;
        let mut inv = IkfacInverse::build(&base, gamma, 6, f64::INFINITY);
        let delta = moved.delta_from(&base);
        assert_eq!(inv.update(&delta, gamma), UpdateOutcome::Updated);
        let fresh = IkfacInverse::build(&moved, gamma, 6, f64::INFINITY);
        let stale = IkfacInverse::build(&base, gamma, 6, f64::INFINITY);
        let g = rand_grads(&arch, 9);
        let (u_upd, u_fresh, u_stale) = (inv.apply(&g), fresh.apply(&g), stale.apply(&g));
        let mut err_upd = 0.0;
        let mut err_stale = 0.0;
        for i in 0..arch.num_layers() {
            err_upd += u_upd.0[i].sub(&u_fresh.0[i]).frob_norm().powi(2);
            err_stale += u_stale.0[i].sub(&u_fresh.0[i]).frob_norm().powi(2);
        }
        assert!(
            err_upd < err_stale,
            "rank-k correction did not improve on the stale inverse: \
             {err_upd} vs {err_stale}"
        );
    }

    #[test]
    fn full_rank_update_matches_full_rebuild() {
        // With rank ≥ n the Woodbury correction is exact: applying the
        // updated inverse must match a from-scratch rebuild at the new
        // stats up to roundoff.
        let (arch, base, moved) = toy_stats_pair();
        let gamma = 0.8;
        let max_dim = (0..arch.num_layers())
            .map(|i| base.aa[i].rows.max(base.gg[i].rows))
            .max()
            .unwrap();
        let mut inv = IkfacInverse::build(&base, gamma, max_dim, f64::INFINITY);
        let delta = moved.delta_from(&base);
        assert_eq!(inv.update(&delta, gamma), UpdateOutcome::Updated);
        let fresh = IkfacInverse::build(&moved, gamma, max_dim, f64::INFINITY);
        let g = rand_grads(&arch, 10);
        let (u_upd, u_fresh) = (inv.apply(&g), fresh.apply(&g));
        for i in 0..arch.num_layers() {
            let rel = u_upd.0[i].sub(&u_fresh.0[i]).max_abs()
                / (1.0 + u_fresh.0[i].max_abs());
            assert!(rel < 1e-6, "layer {i}: rel err {rel}");
        }
    }

    #[test]
    fn update_replay_is_deterministic() {
        // Same (base, delta, γ) → bit-identical corrected inverse —
        // the property checkpoint resume relies on.
        let (arch, base, moved) = toy_stats_pair();
        let gamma = 0.5;
        let delta = moved.delta_from(&base);
        let g = rand_grads(&arch, 11);
        let mut run = || {
            let mut inv = IkfacInverse::build(&base, gamma, 4, f64::INFINITY);
            assert_eq!(inv.update(&delta, gamma), UpdateOutcome::Updated);
            inv.apply(&g)
        };
        let (u1, u2) = (run(), run());
        for (a, b) in u1.0.iter().zip(u2.0.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
