//! Training coordinator: CLI parsing, train configuration, and the
//! training loop that composes datasets, backends and optimizers.

pub mod cli;
pub mod trainer;

pub use cli::Args;
pub use trainer::{LogRow, Problem, TrainConfig, Trainer};
