//! Cholesky factorization for symmetric positive-definite matrices.
//!
//! This is the workhorse behind every factor inversion in K-FAC: the
//! damped Kronecker factors `Ā + πγI` and `G + (γ/π)I` are SPD by
//! construction, so their inverses (Section 4.2) are computed by a
//! Cholesky factorization followed by two triangular solves per column.

use super::Mat;

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
pub struct Cholesky {
    pub l: Mat,
}

impl Cholesky {
    /// Factorize an SPD matrix. Returns `None` if a non-positive pivot is
    /// hit (matrix not positive definite to working precision).
    pub fn new(a: &Mat) -> Option<Cholesky> {
        assert!(a.is_square(), "cholesky: non-square");
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // dot of rows i and j of L over first j entries
                let mut s = a.at(i, j);
                let (ri, rj) = (l.row(i), l.row(j));
                for k in 0..j {
                    s -= ri[k] * rj[k];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l.set(i, j, s.sqrt());
                } else {
                    l.set(i, j, s / l.at(j, j));
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Factorize, adding increasing diagonal jitter on failure.
    /// K-FAC's running covariance estimates are PSD but can be numerically
    /// semi-definite early in training; the caller's damping usually makes
    /// them PD, and this is the last-resort fallback.
    pub fn new_jittered(a: &Mat) -> Cholesky {
        if let Some(c) = Cholesky::new(a) {
            return c;
        }
        let scale = (a.trace() / a.rows as f64).abs().max(1e-300);
        let mut jitter = 1e-12 * scale;
        for _ in 0..40 {
            if let Some(c) = Cholesky::new(&a.add_diag(jitter)) {
                return c;
            }
            jitter *= 10.0;
        }
        panic!("cholesky: matrix could not be jittered to PD");
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            let ri = self.l.row(i);
            for k in 0..i {
                s -= ri[k] * y[k];
            }
            y[i] = s / ri[i];
        }
        // backward: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l.at(k, i) * x[k];
            }
            x[i] = s / self.l.at(i, i);
        }
        x
    }

    /// Solve `A X = B` column-by-column.
    pub fn solve(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.l.rows);
        let bt = b.transpose();
        let mut xt = Mat::zeros(b.cols, b.rows);
        for c in 0..b.cols {
            let x = self.solve_vec(bt.row(c));
            xt.row_mut(c).copy_from_slice(&x);
        }
        xt.transpose()
    }

    /// Dense inverse `A⁻¹`.
    pub fn inverse(&self) -> Mat {
        let n = self.l.rows;
        self.solve(&Mat::eye(n)).symmetrize()
    }

    /// log det(A) = 2 Σ log L_ii.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l.at(i, i).ln()).sum::<f64>() * 2.0
    }
}

/// Convenience: SPD inverse with jitter fallback.
pub fn spd_inverse(a: &Mat) -> Mat {
    Cholesky::new_jittered(a).inverse()
}

/// Convenience: SPD solve with jitter fallback.
pub fn spd_solve(a: &Mat, b: &Mat) -> Mat {
    Cholesky::new_jittered(a).solve(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let x = Mat::randn(n + 4, n, 1.0, rng);
        x.matmul_tn(&x).add_diag(0.5)
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 20] {
            let a = random_spd(n, &mut rng);
            let c = Cholesky::new(&a).unwrap();
            let rec = c.l.matmul_nt(&c.l);
            assert!(rec.sub(&a).max_abs() < 1e-9 * (1.0 + a.max_abs()));
        }
    }

    #[test]
    fn solve_and_inverse() {
        let mut rng = Rng::new(2);
        let a = random_spd(12, &mut rng);
        let c = Cholesky::new(&a).unwrap();
        let b = Mat::randn(12, 3, 1.0, &mut rng);
        let x = c.solve(&b);
        assert!(a.matmul(&x).sub(&b).max_abs() < 1e-8);
        let inv = c.inverse();
        assert!(a.matmul(&inv).sub(&Mat::eye(12)).max_abs() < 1e-8);
    }

    #[test]
    fn non_pd_returns_none_and_jitter_recovers() {
        let mut a = Mat::eye(3);
        a.set(2, 2, -1.0);
        assert!(Cholesky::new(&a).is_none());
        // PSD (rank-deficient) case: jitter must recover.
        let v = Mat::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let psd = v.matmul_nt(&v); // rank 1
        let c = Cholesky::new_jittered(&psd);
        assert!(c.l.at(0, 0) > 0.0);
    }

    #[test]
    fn logdet_matches_known() {
        let a = Mat::from_vec(2, 2, vec![4.0, 0.0, 0.0, 9.0]);
        let c = Cholesky::new(&a).unwrap();
        assert!((c.logdet() - (36.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn property_solve_random_many_seeds() {
        // dependency-free property test: many random SPD systems
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let n = 1 + rng.below(16);
            let a = random_spd(n, &mut rng);
            let b = Mat::randn(n, 2, 1.0, &mut rng);
            let x = spd_solve(&a, &b);
            let resid = a.matmul(&x).sub(&b).max_abs();
            assert!(resid < 1e-7, "seed={seed} n={n} resid={resid}");
        }
    }
}
