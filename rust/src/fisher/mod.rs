//! Kronecker-factored Fisher approximations (paper Sections 3–5).
//!
//! - [`stats`]: per-batch second moments `Ā_{i,j}`, `G_{i,j}` and their
//!   online exponentially-decayed estimates (Section 5).
//! - [`damping`]: the factored Tikhonov technique (Section 6.3) with the
//!   trace-norm `π_i`.
//! - [`blockdiag`]: the block-diagonal inverse `F̌⁻¹` (Section 4.2).
//! - [`tridiag`]: the block-tridiagonal inverse `F̂⁻¹` (Section 4.3),
//!   built from the Ψ/Σ/Λ/Ξ machinery and the Appendix-B structured
//!   inverse.
//! - [`ekfac`]: diagonal rescaling in the Kronecker eigenbasis (George
//!   et al. 2018).
//! - [`kfc`]: Kronecker Factors for Convolution (Grosse & Martens
//!   2016) — patch/spatially-averaged factor semantics for conv
//!   layers, sharing the block-diagonal inverse machinery.
//! - [`precond`]: the open [`Preconditioner`] seam + registry through
//!   which the optimizer reaches all of the above (and external
//!   structures can plug in).
//! - [`exact`]: dense exact `F` and exact `F̃` over a layer range for
//!   small networks — the substrate behind the Figure 2/3/5/6
//!   structure experiments.

pub mod blockdiag;
pub mod damping;
pub mod ekfac;
pub mod exact;
pub mod kfc;
pub mod precond;
pub mod stats;
pub mod tridiag;

pub use blockdiag::BlockDiagInverse;
pub use ekfac::EkfacInverse;
pub use kfc::KfcInverse;
pub use precond::{PrecondRef, Preconditioner};
pub use stats::{KfacStats, RawStats};
pub use tridiag::TridiagInverse;

use crate::linalg::{KronBasis, Mat};
use crate::nn::Params;

/// Reject NaN/Inf-poisoned factor statistics *before* they reach a
/// factorization, with a message naming the structure and layer (the
/// eigensolver's own guard can only report matrix dimensions). Called
/// by every per-layer inverse build.
pub(crate) fn check_factors_finite(structure: &str, layer: usize, aa: &Mat, gg: &Mat) {
    assert!(
        aa.all_finite(),
        "{structure}: non-finite activation statistics Ā for layer {layer} — \
         refusing to build an inverse from poisoned factors"
    );
    assert!(
        gg.all_finite(),
        "{structure}: non-finite pre-activation-gradient statistics G for layer {layer} — \
         refusing to build an inverse from poisoned factors"
    );
}

/// A built approximate inverse Fisher: applies `F₀⁻¹` to a
/// gradient-shaped `Params` (i.e. computes the update proposal
/// `Δ = -F₀⁻¹ ∇h` up to sign). Produced by a [`Preconditioner`] at
/// every inverse refresh.
pub trait FisherInverse {
    fn apply(&self, grads: &Params) -> Params;

    /// The per-layer Kronecker eigenbases `(U_A, U_G)` when this
    /// inverse is a diagonal operator in an eigenbasis (EKFAC); `None`
    /// for structures without one (the default). The optimizer hands
    /// these to `ModelBackend::grad_sq_in_basis` (the backend seam) to
    /// project per-example gradients for the amortized scale
    /// re-estimation.
    fn eigenbases(&self) -> Option<&[KronBasis]> {
        None
    }

    /// Replace the diagonal scales with externally re-estimated
    /// second moments `scales` (one weight-shaped matrix per layer),
    /// damped by `γ²`. Returns `false` when the structure has no
    /// re-estimable scales (the default no-op).
    fn set_scales(&mut self, _scales: &[Mat], _gamma: f64) -> bool {
        false
    }
}
