//! Minimal scoped-thread parallelism (a tiny rayon substitute).
//!
//! The K-FAC hot paths that benefit from threads on the Rust side are the
//! dense matmuls in `linalg` (layer-sized GEMMs, covariance updates,
//! preconditioner application). We split the output row range into one
//! contiguous chunk per worker and run them under `std::thread::scope`,
//! so no `'static` bounds or channels are needed.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (cores − 1, at least 1), overridable
/// with the `KFAC_THREADS` environment variable.
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("KFAC_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get().saturating_sub(1).max(1))
                .unwrap_or(1)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Chunking heuristic for flop-shaped work (the GEMM macro-kernel and
/// row loops): the smallest chunk of `items` whose cost reaches
/// `TARGET_FLOPS`, so tiny problems run inline on the caller thread and
/// only work that amortizes a thread spawn is split across the pool.
pub fn chunk_for_flops(items: usize, flops_per_item: usize) -> usize {
    const TARGET_FLOPS: usize = 1 << 16;
    (TARGET_FLOPS / flops_per_item.max(1)).clamp(1, items.max(1))
}

/// Run `body(lo, hi)` over a partition of `0..n` into contiguous chunks,
/// one per worker. `min_chunk` bounds splitting overhead: if
/// `n <= min_chunk` (or one worker), runs inline on the caller thread.
pub fn par_ranges<F>(n: usize, min_chunk: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let workers = num_threads().min(n.div_ceil(min_chunk.max(1))).max(1);
    if workers == 1 || n == 0 {
        body(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let lo = w * chunk;
            let hi = ((w + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            s.spawn(move || body(lo, hi));
        }
    });
}

/// Parallel map over indices `0..n`, collecting results in order.
pub fn par_map<T, F>(n: usize, min_chunk: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        par_ranges(n, min_chunk, |lo, hi| {
            let p = out_ptr; // capture by copy
            for i in lo..hi {
                // SAFETY: ranges from par_ranges are disjoint, so each
                // element is written by exactly one worker.
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Parallel map for non-`Default` payloads (results are `Send` only).
pub fn par_map_send<T: Send>(
    n: usize,
    min_chunk: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let ptr = SendPtr(out.as_mut_ptr());
        par_ranges(n, min_chunk, |lo, hi| {
            let p = ptr;
            for i in lo..hi {
                // SAFETY: disjoint ranges; each slot written exactly once.
                unsafe { *p.0.add(i) = Some(f(i)) };
            }
        });
    }
    out.into_iter().map(|o| o.expect("par_map_send: slot not filled")).collect()
}

struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_ranges_covers_everything_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_ranges(n, 16, |lo, hi| {
            for i in lo..hi {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_matches_serial() {
        let got = par_map(1000, 8, |i| (i * i) as u64);
        let want: Vec<u64> = (0..1000).map(|i| (i * i) as u64).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn small_n_runs_inline() {
        let got = par_map(3, 1000, |i| i);
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn chunk_for_flops_bounds() {
        // cheap items coalesce, expensive items split singly
        assert_eq!(chunk_for_flops(1000, 1), 1000);
        assert_eq!(chunk_for_flops(1_000_000, 8), (1 << 16) / 8);
        assert_eq!(chunk_for_flops(64, 1 << 20), 1);
        // degenerate inputs stay in range
        assert_eq!(chunk_for_flops(0, 0), 1);
        assert!(chunk_for_flops(5, 0) <= 5);
    }
}
