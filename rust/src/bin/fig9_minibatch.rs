//! Figure 9 — per-iteration and per-training-case progress as a
//! function of the mini-batch size m. The paper's findings to
//! reproduce:
//!  - K-FAC **with** momentum: per-iteration progress grows superlinearly
//!    in m (visible as *per-case* progress improving with m),
//!  - K-FAC **without** momentum: roughly linear in m (per-case progress
//!    flat or worse with m),
//!  - SGD: increasing m helps per-iteration progress much less.
//!
//! Uses the scaled 16×16 autoencoder (rust backend) so the sweep runs
//! in minutes. Output: per-run CSVs + a summary table.

use kfac::data::mnist_like;
use kfac::experiments::{cached_run, results_dir, run_variant_with_backend, scaled, RunCfg, Variant};
use kfac::fisher::precond;
use kfac::nn::{Act, Arch};
use kfac::optim::BatchSchedule;
use kfac::util::write_csv;

fn main() {
    println!("== Figure 9: progress vs mini-batch size m ==");
    let arch = Arch::autoencoder(&[256, 100, 40, 12, 40, 100, 256], Act::Tanh);
    let n = scaled(4000, 1000);
    let ds = mnist_like::autoencoder_dataset(n, 16, 0);
    let iters = scaled(100, 30);
    let ms = [125usize, 250, 500, 1000, 2000];

    let mut summary: Vec<Vec<f64>> = Vec::new();
    println!(
        "\n{:>22} {:>6} {:>12} {:>14} {:>14}",
        "variant", "m", "final_err", "err@iter_half", "cases_total"
    );
    let variants: Vec<(&str, fn() -> Variant)> = vec![
        ("kfac_tridiag_mom", || {
            Variant::kfac("kfac", precond::block_tridiag(), true, 5.0)
        }),
        ("kfac_tridiag_nomom", || {
            Variant::kfac("kfac_nm", precond::block_tridiag(), false, 5.0)
        }),
        ("kfac_blkdiag_mom", || {
            Variant::kfac("kfac_bd", precond::block_diag(), true, 5.0)
        }),
        ("sgd_nag", || Variant::sgd("sgd", 0.02, 0.99)),
    ];
    for (vname, mk) in variants {
        for &m in &ms {
            if m > n {
                continue;
            }
            let tag = format!("fig9_{vname}_m{m}");
            let cfg = RunCfg {
                iters,
                schedule: BatchSchedule::Fixed(m),
                eval_every: 5,
                eval_rows: 1000.min(n),
                seed: 0,
                init_seed: 1,
            };
            let log = cached_run(&tag, || {
                let mut backend = kfac::backend::RustBackend::new(arch.clone());
                run_variant_with_backend(&mut backend, &ds, &cfg, mk(), &tag)
            });
            let last = log.last().unwrap();
            let half = log
                .iter()
                .find(|r| r.iter >= iters / 2)
                .unwrap_or(last);
            println!(
                "{vname:>22} {m:>6} {:>12.5} {:>14.5} {:>14.0}",
                last.train_err, half.train_err, last.cases
            );
            summary.push(vec![
                match vname {
                    "kfac_tridiag_mom" => 0.0,
                    "kfac_tridiag_nomom" => 1.0,
                    "kfac_blkdiag_mom" => 2.0,
                    _ => 3.0,
                },
                m as f64,
                last.train_err,
                half.train_err,
                last.cases,
            ]);
        }
    }

    // Paper-shape check: K-FAC+momentum benefits from larger m per
    // iteration far more than SGD does.
    let final_err = |variant: f64, m: f64| {
        summary
            .iter()
            .find(|r| r[0] == variant && r[1] == m)
            .map(|r| r[2])
            .unwrap_or(f64::NAN)
    };
    let m_max = *ms.iter().filter(|&&m| m <= n).max().unwrap() as f64;
    let kfac_gain = final_err(0.0, 125.0) / final_err(0.0, m_max);
    let sgd_gain = final_err(3.0, 125.0) / final_err(3.0, m_max);
    println!(
        "\nper-iteration benefit of 16× larger batches (err ratio small→large m):"
    );
    println!("  K-FAC+momentum: {kfac_gain:.2}×    SGD: {sgd_gain:.2}×");
    if kfac_gain.is_finite() && sgd_gain.is_finite() {
        assert!(
            kfac_gain > sgd_gain,
            "K-FAC should benefit more from large batches than SGD"
        );
        println!("OK: K-FAC's per-iteration progress scales better with m than SGD's");
    }

    let path = results_dir().join("fig9_summary.csv");
    write_csv(
        &path,
        &["variant", "m", "final_err", "half_err", "cases"],
        &summary,
    )
    .unwrap();
    println!("wrote {}", path.display());
}
