//! FACES substitute: low-rank Gaussian "eigenface" images.
//!
//! The Olivetti faces used by the paper are 25×25 (625-dim), real-valued
//! and standardized; the autoencoder uses a squared-error (Gaussian)
//! output layer. We synthesize from the same statistical family:
//! a smooth mean face plus a random smooth low-rank basis with decaying
//! coefficient variances plus pixel noise, then per-dimension
//! standardization — preserving the regression/Gaussian-output code
//! path and the spectrum shape that makes FACES the "hard" problem.

use super::Dataset;
use crate::linalg::Mat;
use crate::rng::Rng;

/// Smooth random field on a `side × side` grid (sum of random cosines).
fn smooth_field(side: usize, waves: usize, rng: &mut Rng) -> Vec<f64> {
    let mut img = vec![0.0; side * side];
    for _ in 0..waves {
        let fx = 0.5 + 2.5 * rng.uniform();
        let fy = 0.5 + 2.5 * rng.uniform();
        let phx = 6.28 * rng.uniform();
        let phy = 6.28 * rng.uniform();
        let amp = rng.normal();
        for y in 0..side {
            for x in 0..side {
                let u = x as f64 / side as f64;
                let v = y as f64 / side as f64;
                img[y * side + x] +=
                    amp * (6.28 * fx * u + phx).cos() * (6.28 * fy * v + phy).cos();
            }
        }
    }
    img
}

/// Generate `n` standardized face-like images of `side²` dims.
pub fn autoencoder_dataset(n: usize, side: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let d = side * side;
    let rank = 24usize;
    // basis of smooth fields, coefficient std decaying like 1/(1+i/4)
    let basis: Vec<Vec<f64>> = (0..rank).map(|_| smooth_field(side, 4, &mut rng)).collect();
    let mean = smooth_field(side, 3, &mut rng);
    let mut x = Mat::zeros(n, d);
    for r in 0..n {
        let row = x.row_mut(r);
        row.copy_from_slice(&mean);
        for (i, b) in basis.iter().enumerate() {
            let c = rng.normal() / (1.0 + i as f64 / 4.0);
            for (pix, bv) in row.iter_mut().zip(b.iter()) {
                *pix += c * bv;
            }
        }
        for pix in row.iter_mut() {
            *pix += 0.1 * rng.normal();
        }
    }
    // standardize per dimension
    for c in 0..d {
        let mut mu = 0.0;
        for r in 0..n {
            mu += x.at(r, c);
        }
        mu /= n as f64;
        let mut var = 0.0;
        for r in 0..n {
            var += (x.at(r, c) - mu).powi(2);
        }
        var /= (n - 1).max(1) as f64;
        let sd = var.sqrt().max(1e-8);
        for r in 0..n {
            let v = (x.at(r, c) - mu) / sd;
            x.set(r, c, v);
        }
    }
    Dataset::new(x.clone(), x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standardized_real_valued() {
        let ds = autoencoder_dataset(300, 25, 1);
        assert_eq!(ds.x.cols, 625);
        // each column ~ zero mean, unit variance
        for c in [0usize, 100, 624] {
            let mut mu = 0.0;
            for r in 0..300 {
                mu += ds.x.at(r, c);
            }
            mu /= 300.0;
            assert!(mu.abs() < 1e-10, "col {c} mean {mu}");
        }
        // has negative values (real-valued, not [0,1])
        assert!(ds.x.data.iter().any(|v| *v < -0.5));
    }

    #[test]
    fn low_rank_structure_present() {
        // cross-case correlation should be far from identity
        let ds = autoencoder_dataset(100, 25, 2);
        let g = ds.x.matmul_nt(&ds.x);
        let mut off = 0.0;
        let mut count = 0;
        for r in 0..20 {
            for c in 0..20 {
                if r != c {
                    off += g.at(r, c).abs();
                    count += 1;
                }
            }
        }
        let diag: f64 = (0..20).map(|i| g.at(i, i)).sum::<f64>() / 20.0;
        let off_avg = off / count as f64;
        assert!(off_avg > 0.05 * diag, "off={off_avg} diag={diag}");
    }
}
