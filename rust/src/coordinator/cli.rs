//! Dependency-free CLI argument parsing: positional subcommand plus
//! `--key value` / `--key=value` / bare `--flag` options.
//!
//! Two entry points:
//!
//! * [`Args::parse`] — lenient: unknown options are collected, a `--key`
//!   followed by a non-option becomes a key/value pair, a trailing
//!   `--key` becomes a flag. Used by the fig/experiment binaries whose
//!   option sets are fluid.
//! * [`Args::parse_checked`] — strict, for the main `kfac` binary: every
//!   option must be declared (value-taking or flag), a value option with
//!   no value is a usage error, and unknown `--options` are errors
//!   instead of being silently ignored (a typo like `--itres 500` must
//!   not become a default-valued run).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.options.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            }
        }
        out
    }

    /// Strict parse against a declared option vocabulary. `value_opts`
    /// take a value (`--key value` or `--key=value`); `flag_opts` are
    /// bare booleans (`--flag`, or `--flag=true`). Errors (for the
    /// binary to print with its usage text) on: an unknown `--option`, a
    /// value option with no value (end of argv or another `--option`
    /// next), and a flag option given a separate value.
    pub fn parse_checked(
        argv: impl IntoIterator<Item = String>,
        value_opts: &[&str],
        flag_opts: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                if value_opts.contains(&key) {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next_if(|n| !n.starts_with("--"))
                            .ok_or_else(|| format!("option --{key} requires a value"))?,
                    };
                    out.options.insert(key.to_string(), v);
                } else if flag_opts.contains(&key) {
                    out.options.insert(key.to_string(), inline.unwrap_or_else(|| "true".into()));
                } else {
                    return Err(format!("unknown option --{key}"));
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                return Err(format!("unexpected positional argument '{a}'"));
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    fn checked(s: &str) -> Result<Args, String> {
        Args::parse_checked(
            s.split_whitespace().map(str::to_string),
            &["problem", "iters", "seed"],
            &["momentum", "quick"],
        )
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --problem mnist_ae --iters=200 --momentum --seed 7");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("problem"), Some("mnist_ae"));
        assert_eq!(a.get_usize("iters", 0), 200);
        assert!(a.get_flag("momentum"));
        assert_eq!(a.get_usize("seed", 0), 7);
        assert_eq!(a.get_f64("lr", 0.5), 0.5);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --quick");
        assert!(a.get_flag("quick"));
    }

    #[test]
    fn checked_accepts_declared_options() {
        let a = checked("train --problem mnist_ae --iters=200 --momentum --seed 7").unwrap();
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("problem"), Some("mnist_ae"));
        assert_eq!(a.get_usize("iters", 0), 200);
        assert!(a.get_flag("momentum"));
    }

    #[test]
    fn checked_rejects_unknown_option() {
        // The lenient parser would silently collect the typo; the strict
        // one must error so the binary can print usage.
        let err = checked("train --itres 500").unwrap_err();
        assert!(err.contains("--itres"), "got: {err}");
        assert!(checked("train --problem mnist_ae").is_ok());
    }

    #[test]
    fn checked_rejects_trailing_value_option() {
        // Regression: the lenient parser used to reach for `it.next()`
        // here; with nothing after `--seed` this must be a usage error,
        // never a panic or a silent flag.
        let err = checked("train --seed").unwrap_err();
        assert!(err.contains("--seed") && err.contains("value"), "got: {err}");
    }

    #[test]
    fn checked_rejects_value_option_followed_by_option() {
        let err = checked("train --seed --momentum").unwrap_err();
        assert!(err.contains("--seed"), "got: {err}");
    }

    #[test]
    fn lenient_trailing_value_option_degrades_to_flag() {
        // The lenient path must also never panic on a trailing option.
        let a = parse("train --seed");
        assert_eq!(a.get("seed"), Some("true"));
    }
}
