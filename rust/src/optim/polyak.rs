//! Exponentially-decayed iterate averaging (paper Section 13): the
//! "averaged" estimate is `ξ·avg + (1−ξ)·θ_k` with ξ = 0.99, and the
//! reported error is the min over {current, averaged}.
//!
//! [`PolyakAverager::get`] returns `None` until the first update —
//! callers evaluating before any training step (or on zero-iteration
//! runs) must treat the averaged estimate as absent, not panic.

use crate::nn::Params;

pub struct PolyakAverager {
    pub xi: f64,
    avg: Option<Params>,
}

impl PolyakAverager {
    pub fn new(xi: f64) -> PolyakAverager {
        PolyakAverager { xi, avg: None }
    }

    /// Rebuild from checkpointed state (`avg` is `None` when the
    /// averager had not yet absorbed an update).
    pub fn restore(xi: f64, avg: Option<Params>) -> PolyakAverager {
        PolyakAverager { xi, avg }
    }

    pub fn update(&mut self, params: &Params) {
        match &mut self.avg {
            None => self.avg = Some(params.clone()),
            Some(a) => {
                for (am, pm) in a.0.iter_mut().zip(params.0.iter()) {
                    am.ema(self.xi, 1.0 - self.xi, pm);
                }
            }
        }
    }

    pub fn get(&self) -> Option<&Params> {
        self.avg.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn averages_converge_to_constant_input() {
        let p = Params(vec![Mat::filled(2, 2, 3.0)]);
        let mut avg = PolyakAverager::new(0.5);
        avg.update(&Params(vec![Mat::filled(2, 2, 1.0)]));
        for _ in 0..30 {
            avg.update(&p);
        }
        let a = avg.get().unwrap();
        assert!((a.0[0].at(0, 0) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn empty_averager_reports_absent_not_panicking() {
        let avg = PolyakAverager::new(0.99);
        assert!(avg.get().is_none());
    }

    #[test]
    fn restore_roundtrips() {
        let p = Params(vec![Mat::filled(2, 2, 3.0)]);
        let mut avg = PolyakAverager::new(0.9);
        avg.update(&p);
        let re = PolyakAverager::restore(avg.xi, avg.get().cloned());
        assert_eq!(re.xi, 0.9);
        assert!(re.get().unwrap() == &p);
        let empty = PolyakAverager::restore(0.5, None);
        assert!(empty.get().is_none());
    }
}
