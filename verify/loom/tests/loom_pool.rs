//! Exhaustive interleaving checks for the `par` synchronization
//! protocols, driven by [loom](https://docs.rs/loom). Each `#[test]`
//! wraps one protocol in `loom::model`, which re-runs the closure under
//! every schedule its bounded exploration can reach and fails on
//! deadlock, livelock, missed-wakeup hangs, or (via `loom::cell`)
//! unsynchronized memory access — the properties "the tests passed"
//! never established.
//!
//! Build with `RUSTFLAGS="--cfg loom"` (the harness crate's README/CI
//! job); without the cfg this file compiles to an empty test binary.
//! Run with `--test-threads=1`: the panic-propagation model installs a
//! process-global panic hook.
#![cfg(loom)]

use kfac_verify_loom::par::model;
use loom::cell::UnsafeCell;
use loom::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use loom::sync::Arc;

/// A loom model with a preemption bound (schedules with more than
/// `preemptions` forced context switches per thread are pruned — the
/// standard way to keep condvar-heavy models tractable; bound 2 is
/// loom's documented sweet spot for catching real bugs).
fn model_with(preemptions: usize, f: impl Fn() + Send + Sync + 'static) {
    let mut b = loom::model::Builder::new();
    b.preemption_bound = Some(preemptions);
    b.max_branches = 50_000;
    b.check(f);
}

/// Fixed-size slots probed through loom's access-tracking cells: any
/// write that is not happens-before-ordered against every other access
/// fails the model. Shared across threads by the dispatch machinery, so
/// it must assert `Sync` itself — soundness is exactly what the model
/// verifies.
struct Slots(Vec<UnsafeCell<u64>>);
// SAFETY (test-only): concurrent access discipline is enforced by loom's
// UnsafeCell tracking; an actually-unsynchronized access fails the test
// rather than going unnoticed.
unsafe impl Sync for Slots {}
// SAFETY (test-only): same as above — ownership transfer is tracked.
unsafe impl Send for Slots {}

impl Slots {
    fn new(n: usize) -> Slots {
        Slots((0..n).map(|_| UnsafeCell::new(0)).collect())
    }

    fn write(&self, i: usize, v: u64) {
        // SAFETY: loom verifies exclusive access at model time.
        self.0[i].with_mut(|p| unsafe { *p = v });
    }

    fn read(&self, i: usize) -> u64 {
        // SAFETY: loom verifies no concurrent writer at model time.
        self.0[i].with(|p| unsafe { *p })
    }
}

/// The core fork-join claim: a pooled dispatch's disjoint chunk writes
/// are all visible to the caller when `par_ranges` returns, under every
/// schedule — i.e. the latch's AcqRel count_down / Acquire done pair
/// really publishes the workers' writes.
#[test]
fn dispatch_publishes_disjoint_chunk_writes() {
    model_with(2, || {
        let pool = model::pool();
        let worker = loom::thread::spawn(move || model::worker(pool));
        let slots = Slots::new(2);
        model::par_ranges_on(pool, 2, 2, |lo, hi| {
            for i in lo..hi {
                slots.write(i, (i as u64 + 1) * 10);
            }
        });
        // Dispatch returned ⇒ every chunk's write must be ordered
        // before these reads (loom fails the access if not).
        assert_eq!(slots.read(0), 10);
        assert_eq!(slots.read(1), 20);
        model::close(pool);
        worker.join().unwrap();
    });
}

/// Deadlock freedom of nested dispatch: a worker chunk that itself
/// dispatches onto the same (single-worker) pool must complete — the
/// help-first drain plus the bounded park must cover every schedule,
/// including the one where everyone parks at once.
#[test]
fn nested_dispatch_under_park_completes() {
    model_with(2, || {
        let pool = model::pool();
        let worker = loom::thread::spawn(move || model::worker(pool));
        let slots = Slots::new(2);
        let hits = AtomicUsize::new(0);
        model::par_ranges_on(pool, 2, 2, |lo, hi| {
            for i in lo..hi {
                // inner dispatch from inside a chunk (runs on either
                // the worker or the caller, schedule-dependent)
                model::par_ranges_on(pool, 2, 2, |ilo, ihi| {
                    hits.fetch_add(ihi - ilo, Ordering::AcqRel);
                });
                slots.write(i, 1);
            }
        });
        assert_eq!(slots.read(0) + slots.read(1), 2);
        assert_eq!(hits.load(Ordering::Acquire), 4, "2 outer chunks × 2 inner items");
        model::close(pool);
        worker.join().unwrap();
    });
}

/// A detached job's result round-trips through the slot under every
/// schedule, and the job's side effects are published to the collector
/// (the result mutex provides the happens-before edge).
#[test]
fn job_collect_returns_value_across_all_interleavings() {
    model_with(3, || {
        let pool = model::pool();
        let worker = loom::thread::spawn(move || model::worker(pool));
        let slots = Arc::new(Slots::new(1));
        let s2 = Arc::clone(&slots);
        let h = model::spawn_job_on(pool, move || {
            s2.write(0, 77);
            41u64 + 1
        });
        assert_eq!(h.collect(), 42);
        // collect returned ⇒ the job's cell write is ordered before
        // this read.
        assert_eq!(slots.read(0), 77);
        model::close(pool);
        worker.join().unwrap();
    });
}

/// With no worker at all, `collect` must execute the queued job itself
/// (the help-first drain picks its own job off the queue) — the
/// zero-progress-from-others schedule.
#[test]
fn collect_self_executes_when_no_worker_takes_the_job() {
    model_with(3, || {
        let pool = model::pool();
        let h = model::spawn_job_on(pool, || 7u64 * 3);
        assert_eq!(h.collect(), 21);
        model::close(pool);
    });
}

/// The dedicated-thread path (`KFAC_POOL=0`): plain condvar wait, no
/// queue to help drain — must still never hang.
#[test]
fn dedicated_thread_job_collects() {
    model_with(3, || {
        let h = model::spawn_job_detached(|| 5u64 + 5);
        assert_eq!(h.collect(), 10);
    });
}

/// `is_done() == true` must imply `try_collect` succeeds — there is no
/// schedule where the done flag is visible before the result is.
#[test]
fn is_done_implies_try_collect_succeeds() {
    model_with(2, || {
        let pool = model::pool();
        let worker = loom::thread::spawn(move || model::worker(pool));
        let mut h = model::spawn_job_on(pool, || 13u64);
        loop {
            if h.is_done() {
                match h.try_collect() {
                    Ok(v) => assert_eq!(v, 13),
                    Err(_) => panic!("is_done true but try_collect failed"),
                }
                break;
            }
            match h.try_collect() {
                Ok(v) => {
                    assert_eq!(v, 13);
                    break;
                }
                Err(back) => h = back,
            }
            loom::thread::yield_now();
        }
        model::close(pool);
        worker.join().unwrap();
    });
}

/// Dropping a handle without collecting neither cancels the job nor
/// wedges the worker: the side effect still happens and the pool shuts
/// down cleanly afterwards.
#[test]
fn job_drop_without_collect_is_clean() {
    model_with(2, || {
        let pool = model::pool();
        let worker = loom::thread::spawn(move || model::worker(pool));
        let ran = Arc::new(AtomicBool::new(false));
        let r2 = Arc::clone(&ran);
        drop(model::spawn_job_on(pool, move || r2.store(true, Ordering::Release)));
        // close() lets the queued job drain before the worker exits, so
        // after join the effect must have happened on every schedule.
        model::close(pool);
        worker.join().unwrap();
        assert!(ran.load(Ordering::Acquire), "dropped job must still run");
    });
}

/// A panicking job delivers its payload exactly once, at collect, on
/// the collecting thread — and the worker that ran it survives to shut
/// down normally (the panic is caught at the job boundary, never
/// unwinding the worker loop).
#[test]
fn panicked_job_propagates_payload_exactly_once() {
    // Suppress the default "thread panicked" stderr spam: this model
    // panics on purpose in every iteration. Global, hence
    // --test-threads=1 for this suite; restored below.
    std::panic::set_hook(Box::new(|_| {}));
    model_with(2, || {
        let pool = model::pool();
        let worker = loom::thread::spawn(move || model::worker(pool));
        let h = model::spawn_job_on(pool, || -> u64 { std::panic::panic_any(1234_usize) });
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h.collect()))
            .expect_err("collect must re-raise the job panic");
        assert_eq!(err.downcast_ref::<usize>(), Some(&1234));
        // the worker must not have unwound — it still serves jobs
        let h2 = model::spawn_job_on(pool, || 8u64);
        assert_eq!(h2.collect(), 8);
        model::close(pool);
        worker.join().unwrap();
    });
    let _ = std::panic::take_hook();
}

/// The async inverse-refresh epoch-swap protocol (`PendingJob`), as
/// `optim::kfac` runs it: submit a build against a shared snapshot,
/// keep "stepping" (reading the snapshot, as a mid-flight checkpoint
/// does) while the build races, then finish and install. Checks, on
/// every schedule: the build's output is correct and published; the
/// stall flag is consistent with `is_done`; and — via loom's cell
/// tracking — mutating the snapshot after `finish` cannot race the
/// builder's reads (the builder's borrow is provably dead).
#[test]
fn epoch_swap_install_vs_step() {
    model_with(2, || {
        let pool = model::pool();
        let worker = loom::thread::spawn(move || model::worker(pool));

        let snap = Arc::new(Slots::new(2));
        snap.write(0, 3);
        snap.write(1, 4);
        let epoch = AtomicUsize::new(7);

        let pending =
            model::submit_build_on(pool, Arc::clone(&snap), 5, |s| s.read(0) + s.read(1));
        assert_eq!(pending.submitted_k(), 5);

        // a "training step" on the stale inverse: checkpoint-style read
        // of the in-flight snapshot, concurrent with the builder
        let ck = pending.input().read(0);
        assert_eq!(ck, 3);

        let done_before = pending.is_done();
        let (inv, returned, stalled) = pending.finish();
        assert_eq!(inv, 7, "build output must round-trip");
        if done_before {
            assert!(!stalled, "a finished build must not count as a stall");
        }

        // install: epoch swap, then the optimizer owns the snapshot
        // again — this write races the builder iff the protocol is
        // wrong, and loom's cell tracking would fail the model.
        epoch.store(epoch.load(Ordering::Acquire) + 1, Ordering::Release);
        returned.write(0, 99);
        assert_eq!(returned.read(0), 99);
        assert_eq!(epoch.load(Ordering::Acquire), 8);

        model::close(pool);
        worker.join().unwrap();
    });
}

/// The latch in isolation: N count_downs vs a parking waiter. The park
/// is bounded and re-checks, so no schedule (including notify-before-
/// park) may hang or let the waiter through early.
#[test]
fn latch_count_down_vs_park() {
    model_with(3, || {
        let latch = model::latch(2);
        let l1 = latch.clone();
        let t1 = loom::thread::spawn(move || l1.count_down());
        let l2 = latch.clone();
        let t2 = loom::thread::spawn(move || l2.count_down());
        latch.park_until_done();
        assert!(latch.done());
        t1.join().unwrap();
        t2.join().unwrap();
    });
}
