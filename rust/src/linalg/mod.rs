//! Dense linear algebra substrate (f64, row-major).
//!
//! Everything K-FAC needs from a LAPACK/BLAS that we do not have:
//! threaded blocked GEMM (all four transpose variants used by the
//! NN/Fisher code) over runtime-dispatched SIMD micro-kernels (see
//! [`simd`]: AVX2/AVX-512 with a scalar reference, `KFAC_SIMD`
//! override), Cholesky factorization / SPD inverses, a Jacobi
//! symmetric eigensolver, PSD matrix square roots, Kronecker-product
//! utilities, and the Appendix-B structured inverse of
//! `A ⊗ B ± C ⊗ D` (see [`stein`]).

pub mod chol;
pub mod eig;
pub mod gemm;
pub mod kron;
pub mod pack;
pub mod simd;
pub mod stein;

pub use chol::Cholesky;
pub use eig::SymEig;
pub use kron::KronBasis;
pub use stein::KronPairInverse;

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 36 {
            for r in 0..self.rows {
                write!(f, "\n  [")?;
                for c in 0..self.cols {
                    write!(f, " {:9.4}", self.at(r, c))?;
                }
                write!(f, " ]")?;
            }
        }
        Ok(())
    }
}

impl Mat {
    // ---------- constructors ----------

    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn filled(rows: usize, cols: usize, v: f64) -> Mat {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "from_vec: size mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Random N(0, sigma^2) entries.
    pub fn randn(rows: usize, cols: usize, sigma: f64, rng: &mut crate::rng::Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = sigma * rng.normal();
        }
        m
    }

    // ---------- element access ----------

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline(always)]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    // ---------- shape ops ----------

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Copy a rectangular block `[r0..r1) x [c0..c1)`.
    pub fn block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut b = Mat::zeros(r1 - r0, c1 - c0);
        for r in r0..r1 {
            b.row_mut(r - r0).copy_from_slice(&self.row(r)[c0..c1]);
        }
        b
    }

    /// Write `src` into the block starting at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, src: &Mat) {
        assert!(r0 + src.rows <= self.rows && c0 + src.cols <= self.cols);
        for r in 0..src.rows {
            let dst = &mut self.row_mut(r0 + r)[c0..c0 + src.cols];
            dst.copy_from_slice(src.row(r));
        }
    }

    /// First `n` rows as a new matrix.
    pub fn top_rows(&self, n: usize) -> Mat {
        self.block(0, n.min(self.rows), 0, self.cols)
    }

    /// Rows selected by `idx` (gather).
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Append a column of ones (homogeneous coordinate ā = [a; 1]).
    pub fn append_ones_col(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols + 1);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols] = 1.0;
        }
        out
    }

    /// Drop the last column (inverse of `append_ones_col`).
    pub fn drop_last_col(&self) -> Mat {
        self.block(0, self.rows, 0, self.cols - 1)
    }

    // ---------- elementwise / vector-space ops ----------

    pub fn map(&self, f: impl Fn(f64) -> f64 + Sync) -> Mat {
        let mut out = self.clone();
        for v in out.data.iter_mut() {
            *v = f(*v);
        }
        out
    }

    pub fn zip_map(&self, other: &Mat, f: impl Fn(f64, f64) -> f64) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (v, &o) in out.data.iter_mut().zip(other.data.iter()) {
            *v = f(*v, o);
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        self.zip_map(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        self.zip_map(other, |a, b| a - b)
    }

    pub fn hadamard(&self, other: &Mat) -> Mat {
        self.zip_map(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f64) -> Mat {
        self.map(|v| v * s)
    }

    /// `self += alpha * other`
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (v, &o) in self.data.iter_mut().zip(other.data.iter()) {
            *v += alpha * o;
        }
    }

    /// `self = beta*self + alpha*other` (the EMA update of Section 5).
    pub fn ema(&mut self, beta: f64, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (v, &o) in self.data.iter_mut().zip(other.data.iter()) {
            *v = beta * *v + alpha * o;
        }
    }

    /// Add `v` to the diagonal (Tikhonov damping).
    pub fn add_diag(&self, v: f64) -> Mat {
        assert!(self.is_square());
        let mut out = self.clone();
        for i in 0..self.rows {
            out.data[i * self.cols + i] += v;
        }
        out
    }

    /// Frobenius inner product `<self, other>`.
    pub fn dot(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data.iter().zip(other.data.iter()).map(|(a, b)| a * b).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.dot(self).sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }

    /// True when every entry is finite (no NaN / ±Inf). The eigensolver
    /// and the per-layer inverse builders use this to reject poisoned
    /// statistics with a descriptive message instead of panicking deep
    /// inside a sort.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self.data[i * self.cols + i]).sum()
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Force exact symmetry: (M + Mᵀ)/2.
    pub fn symmetrize(&self) -> Mat {
        assert!(self.is_square());
        let mut out = self.clone();
        for r in 0..self.rows {
            for c in (r + 1)..self.cols {
                let v = 0.5 * (self.at(r, c) + self.at(c, r));
                out.set(r, c, v);
                out.set(c, r, v);
            }
        }
        out
    }

    // ---------- GEMM family ----------
    //
    // All variants lower onto the packed, cache-blocked, threaded kernel
    // in [`gemm`]; the transposed layouts differ only in the operand
    // strides handed to the packing layer.

    /// `self * other`
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(
            self.cols,
            other.rows,
            "matmul: {}x{} * {}x{}",
            self.rows,
            self.cols,
            other.rows,
            other.cols
        );
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        gemm::gemm_strided(m, n, k, &self.data, k, 1, &other.data, n, 1, &mut out.data);
        out
    }

    /// `selfᵀ * other`  (e.g. covariance updates `Xᵀ X / m`).
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn shape");
        let (m, k, n) = (self.cols, self.rows, other.cols);
        let mut out = Mat::zeros(m, n);
        gemm::gemm_strided(m, n, k, &self.data, 1, m, &other.data, n, 1, &mut out.data);
        out
    }

    /// `self * otherᵀ`  (e.g. layer forward `Ā Wᵀ`).
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        gemm::gemm_strided(m, n, k, &self.data, k, 1, &other.data, 1, k, &mut out.data);
        out
    }

    /// Matrix-vector product `self * v` (GEMM with an `n = 1` operand).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        let mut out = vec![0.0; self.rows];
        gemm::gemm_strided(self.rows, 1, self.cols, &self.data, self.cols, 1, v, 1, 1, &mut out);
        out
    }

    /// General (square, not necessarily SPD) inverse via partial-pivot
    /// Gauss–Jordan. Used only in tests/experiments on small matrices;
    /// the optimizer hot path uses Cholesky.
    pub fn inverse(&self) -> Mat {
        assert!(self.is_square());
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Mat::eye(n);
        for col in 0..n {
            // pivot
            let mut piv = col;
            for r in (col + 1)..n {
                if a.at(r, col).abs() > a.at(piv, col).abs() {
                    piv = r;
                }
            }
            if a.at(piv, col).abs() < 1e-300 {
                panic!("inverse: singular matrix at column {col}");
            }
            if piv != col {
                for c in 0..n {
                    let (x, y) = (a.at(col, c), a.at(piv, c));
                    a.set(col, c, y);
                    a.set(piv, c, x);
                    let (x, y) = (inv.at(col, c), inv.at(piv, c));
                    inv.set(col, c, y);
                    inv.set(piv, c, x);
                }
            }
            let d = 1.0 / a.at(col, col);
            for c in 0..n {
                a.set(col, c, a.at(col, c) * d);
                inv.set(col, c, inv.at(col, c) * d);
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a.at(r, col);
                if f == 0.0 {
                    continue;
                }
                for c in 0..n {
                    let v = a.at(r, c) - f * a.at(col, c);
                    a.set(r, c, v);
                    let v = inv.at(r, c) - f * inv.at(col, c);
                    inv.set(r, c, v);
                }
            }
        }
        inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for p in 0..a.cols {
                    s += a.at(i, p) * b.at(p, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_variants_agree_with_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 4, 5), (17, 9, 23), (64, 32, 48)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let want = naive_matmul(&a, &b);
            assert!(a.matmul(&b).sub(&want).max_abs() < 1e-10);
            assert!(a.transpose().matmul_tn(&b).sub(&want).max_abs() < 1e-10);
            assert!(a.matmul_nt(&b.transpose()).sub(&want).max_abs() < 1e-10);
        }
    }

    #[test]
    fn transpose_involution_and_blocks() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(7, 5, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        let b = a.block(1, 4, 2, 5);
        assert_eq!(b.rows, 3);
        assert_eq!(b.at(0, 0), a.at(1, 2));
        let mut z = Mat::zeros(7, 5);
        z.set_block(1, 2, &b);
        assert_eq!(z.at(3, 4), a.at(3, 4));
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(6, 6, 1.0, &mut rng).add(&Mat::eye(6).scale(3.0));
        let ainv = a.inverse();
        let err = a.matmul(&ainv).sub(&Mat::eye(6)).max_abs();
        assert!(err < 1e-9, "err={err}");
    }

    #[test]
    fn homogeneous_column_helpers() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(3, 4, 1.0, &mut rng);
        let ab = a.append_ones_col();
        assert_eq!(ab.cols, 5);
        assert!((0..3).all(|r| ab.at(r, 4) == 1.0));
        assert_eq!(ab.drop_last_col(), a);
    }

    #[test]
    fn ema_and_axpy() {
        let a = Mat::filled(2, 2, 1.0);
        let mut b = Mat::filled(2, 2, 3.0);
        b.ema(0.5, 0.5, &a);
        assert!((b.at(0, 0) - 2.0).abs() < 1e-15);
        b.axpy(2.0, &a);
        assert!((b.at(1, 1) - 4.0).abs() < 1e-15);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(4, 6, 1.0, &mut rng);
        let v: Vec<f64> = (0..6).map(|i| i as f64).collect();
        let vm = Mat::from_vec(6, 1, v.clone());
        let want = a.matmul(&vm);
        let got = a.matvec(&v);
        for i in 0..4 {
            assert!((got[i] - want.at(i, 0)).abs() < 1e-12);
        }
    }
}
